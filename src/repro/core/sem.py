"""Spectral element method (SEM) reference-element machinery.

Gauss-Legendre-Lobatto (GLL) nodes/weights and the one-dimensional
derivative matrix ``D`` for the degree-N Lagrange basis interpolating the
GLL points, exactly as used by NekBone/hipBone (paper Eq. for S_L^e).

All precompute here is done in numpy float64 regardless of the runtime
dtype — these are setup-time constants, cast once when building operators.
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "gll_nodes_weights",
    "derivative_matrix",
    "reference_element",
    "interpolation_matrix",
    "interp_coords_3d",
    "interp_field_3d",
    "stiffness_matrix_1d",
    "extended_interval_matrices",
    "fast_diagonalization_1d",
]


def _legendre_and_derivative(n: int, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Value and derivative of the Legendre polynomial P_n at points x.

    Three-term recurrence; stable for the modest n (<= 31) used by SEM.
    """
    x = np.asarray(x, dtype=np.float64)
    p_prev = np.ones_like(x)            # P_0
    if n == 0:
        return p_prev, np.zeros_like(x)
    p = x.copy()                        # P_1
    for k in range(1, n):
        p_next = ((2 * k + 1) * x * p - k * p_prev) / (k + 1)
        p_prev, p = p, p_next
    # P'_n via the standard identity (1 - x^2) P'_n = n (P_{n-1} - x P_n)
    with np.errstate(divide="ignore", invalid="ignore"):
        dp = n * (p_prev - x * p) / (1.0 - x * x)
    # Endpoints: P'_n(±1) = (±1)^{n-1} n(n+1)/2
    endv = n * (n + 1) / 2.0
    dp = np.where(x == 1.0, endv, dp)
    dp = np.where(x == -1.0, (-1.0) ** (n - 1) * endv, dp)
    return p, dp


@functools.lru_cache(maxsize=64)
def gll_nodes_weights(n_degree: int) -> tuple[np.ndarray, np.ndarray]:
    """GLL quadrature nodes and weights for polynomial degree ``n_degree``.

    Returns ``(x, w)`` with ``n_degree + 1`` points on [-1, 1].
    Nodes are the endpoints plus the roots of P'_N; weights are
    ``w_i = 2 / (N (N+1) P_N(x_i)^2)``.
    """
    n = int(n_degree)
    if n < 1:
        raise ValueError(f"SEM degree must be >= 1, got {n}")
    if n == 1:
        x = np.array([-1.0, 1.0])
    else:
        # Chebyshev-Gauss-Lobatto initial guess, then Newton on (1-x^2) P'_N.
        x = -np.cos(np.pi * np.arange(n + 1) / n)
        for _ in range(200):
            p, dp = _legendre_and_derivative(n, x)
            # f(x) = (1 - x^2) P'_N(x); f'(x) = -N(N+1) P_N(x)  (GLL identity)
            f = (1.0 - x * x) * dp
            fp = -n * (n + 1) * p
            dx = np.where(np.abs(fp) > 0, f / fp, 0.0)
            # keep the endpoints pinned
            dx[0] = 0.0
            dx[-1] = 0.0
            x = x - dx
            if np.max(np.abs(dx)) < 1e-15:
                break
        x[0], x[-1] = -1.0, 1.0
    p, _ = _legendre_and_derivative(n, x)
    w = 2.0 / (n * (n + 1) * p * p)
    return x, w


@functools.lru_cache(maxsize=64)
def derivative_matrix(n_degree: int) -> np.ndarray:
    """1-D SEM derivative matrix D on the GLL points.

    ``(D u)_i = u'(x_i)`` for ``u`` in the degree-N Lagrange basis.
    ``D[i, j] = (P_N(x_i) / P_N(x_j)) / (x_i - x_j)`` off-diagonal, with
    corner values ∓N(N+1)/4.
    """
    n = int(n_degree)
    x, _ = gll_nodes_weights(n)
    p, _ = _legendre_and_derivative(n, x)
    d = np.zeros((n + 1, n + 1), dtype=np.float64)
    for i in range(n + 1):
        for j in range(n + 1):
            if i != j:
                d[i, j] = (p[i] / p[j]) / (x[i] - x[j])
    d[0, 0] = -n * (n + 1) / 4.0
    d[n, n] = n * (n + 1) / 4.0
    return d


@functools.lru_cache(maxsize=128)
def interpolation_matrix(n_from: int, n_to: int) -> np.ndarray:
    """1-D GLL degree-interpolation matrix J: degree ``n_from`` -> ``n_to``.

    ``J[i, j] = ℓ_j(x_i^{to})`` — the degree-``n_from`` Lagrange basis on the
    GLL nodes evaluated at the degree-``n_to`` GLL nodes, shape
    ``(n_to+1, n_from+1)``.  ``J @ u`` interpolates nodal values and is exact
    for polynomials of degree <= ``n_from``; the tensor-product lift
    ``J ⊗ J ⊗ J`` is the element-local p-multigrid prolongation
    (``n_from < n_to``) and its transpose the restriction.  Evaluated in the
    barycentric form, which is stable on the clustered GLL nodes.
    """
    xf, _ = gll_nodes_weights(int(n_from))
    xt, _ = gll_nodes_weights(int(n_to))
    diff = xf[:, None] - xf[None, :]
    np.fill_diagonal(diff, 1.0)
    wb = 1.0 / np.prod(diff, axis=1)          # barycentric weights
    out = np.zeros((xt.size, xf.size), dtype=np.float64)
    for i, x in enumerate(xt):
        dx = x - xf
        hit = np.isclose(dx, 0.0, atol=1e-14)
        if hit.any():                          # target node coincides (±1 always)
            out[i, np.argmax(hit)] = 1.0
        else:
            t = wb / dx
            out[i] = t / t.sum()
    return out


def interp_coords_3d(j: np.ndarray, coords: np.ndarray) -> np.ndarray:
    """Sample element node coordinates on a different-degree GLL grid.

    ``coords``: (E, (nf+1)^3, 3) in (t, s, r) node order; ``j``: the 1-D
    ``interpolation_matrix(n_from, n_to)``. Exact for the polynomial
    coordinate maps produced by ``mesh.build_box_mesh``, so the coarse level
    of a p-multigrid hierarchy sits on the same curved geometry.
    """
    e = coords.shape[0]
    nf1 = j.shape[1]
    c3 = coords.reshape(e, nf1, nf1, nf1, 3)
    c3 = np.einsum("ra,etsac->etsrc", j, c3)
    c3 = np.einsum("sb,etbrc->etsrc", j, c3)
    c3 = np.einsum("tc,ecsrx->etsrx", j, c3)
    return c3.reshape(e, -1, 3)


def interp_field_3d(j: np.ndarray, field: np.ndarray) -> np.ndarray:
    """Sample an element-local scalar field on a different-degree GLL grid.

    ``field``: (E, (nf+1)^3) in (t, s, r) node order; ``j``: the 1-D
    ``interpolation_matrix(n_from, n_to)``.  The scalar twin of
    :func:`interp_coords_3d` — resamples per-quadrature-point coefficient
    fields (k, λ) when ``operator.coarsen_problem`` rediscretizes a
    p-multigrid level.  Exact on per-element-constant fields (the checker
    family), spectrally accurate on smooth ones.
    """
    e = field.shape[0]
    nf1 = j.shape[1]
    f3 = np.asarray(field).reshape(e, nf1, nf1, nf1)
    f3 = np.einsum("ra,etsa->etsr", j, f3)
    f3 = np.einsum("sb,etbr->etsr", j, f3)
    f3 = np.einsum("tc,ecsr->etsr", j, f3)
    return f3.reshape(e, -1)


@functools.lru_cache(maxsize=64)
def stiffness_matrix_1d(n_degree: int) -> np.ndarray:
    """1-D SEM stiffness matrix on the reference interval [-1, 1].

    ``A[i, j] = Σ_q w_q D[q, i] D[q, j]`` — the weak Laplacian of the
    degree-N Lagrange basis under GLL quadrature (symmetric positive
    semidefinite; the constant mode is its nullspace).  For an affine
    element of length ``h`` the physical stiffness is ``(2/h) A`` and the
    lumped mass is ``(h/2) diag(w)``; these two 1-D matrices are all the
    fast-diagonalization Schwarz setup needs.
    """
    _, w = gll_nodes_weights(int(n_degree))
    d = derivative_matrix(int(n_degree))
    return (d * w[:, None]).T @ d


def extended_interval_matrices(
    n_degree: int,
    overlap: int,
    h: float,
    *,
    has_lo: bool = True,
    has_hi: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """1-D operator on an element interval extended ``overlap`` nodes each way.

    The extended grid is the element's ``N+1`` GLL nodes plus the nearest
    ``overlap`` GLL nodes of each neighbor element (neighbors are
    approximated as mirror images of the element — exact when adjacent
    elements share the spacing ``h``, the usual Nek5000/RS FDM setup).
    The matrices are the 3-element patch-assembled SEM stiffness and lumped
    mass restricted to the extended window, i.e. homogeneous Dirichlet at
    the window ends — the local overlapping-Schwarz subdomain problem.

    Args:
      n_degree: element polynomial degree N.
      overlap: extension width s in GLL nodes, 0 <= s <= N-1.  ``s = 0``
        degenerates to the element block of the patch-assembled operator
        (block Jacobi).
      h: element length along this direction.
      has_lo / has_hi: whether a neighbor element exists on that side.  A
        missing neighbor (physical domain boundary) keeps the element end
        natural (Neumann) and turns the would-be extension slots into
        decoupled identity rows (they carry zero data and are masked off by
        the caller).

    Returns:
      ``(a_ext, b_ext)``: the (m, m) stiffness and the (m,) lumped-mass
      diagonal with ``m = N + 1 + 2*overlap``.
    """
    n = int(n_degree)
    s = int(overlap)
    if not 0 <= s <= n - 1:
        raise ValueError(f"overlap must be in [0, {n - 1}] for N={n}, got {s}")
    _, w = gll_nodes_weights(n)
    a_el = (2.0 / h) * stiffness_matrix_1d(n)
    b_el = (h / 2.0) * w

    npatch = 3 * n + 1
    a = np.zeros((npatch, npatch))
    b = np.zeros(npatch)
    for e, present in enumerate((has_lo, True, has_hi)):
        if not present:
            continue
        sl = slice(e * n, e * n + n + 1)
        a[sl, sl] += a_el
        b[sl] += b_el

    win = slice(n - s, 2 * n + s + 1)
    a_ext = a[win, win].copy()
    b_ext = b[win].copy()
    # absent-neighbor slots: decouple as identity rows (zero data, masked out)
    dummy = b_ext == 0.0
    if dummy.any():
        a_ext[dummy, :] = 0.0
        a_ext[:, dummy] = 0.0
        a_ext[dummy, dummy] = 1.0
        b_ext[dummy] = 1.0
    return a_ext, b_ext


def fast_diagonalization_1d(
    a_ext: np.ndarray, b_ext: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Generalized eigendecomposition ``A t = μ B t`` with ``TᵀBT = I``.

    This is the 1-D factor of the tensor-product fast diagonalization
    (Lynch-Rice-Thomas): with per-direction factors ``(T_d, μ_d)`` the local
    separable operator ``A⊗B⊗B + B⊗A⊗B + B⊗B⊗A`` inverts as

        Â⁻¹ = (T₃⊗T₂⊗T₁) diag(1 / (μ_i + μ_j + μ_k)) (T₃⊗T₂⊗T₁)ᵀ.

    ``B`` is the diagonal lumped mass, so the generalized problem reduces to
    a symmetric eigendecomposition of ``B^{-1/2} A B^{-1/2}``.

    Returns:
      ``(t, mu, s)``: eigenvector matrix (m, m), eigenvalues (m,) ascending,
      and ``s[i] = (TᵀT)_{ii}`` — the diagonal of the identity's image in
      the eigenbasis, used to fold NekBone's algebraic screen ``λI`` into
      the tensor denominators (``λI`` does not tensor-factorize exactly;
      ``diag(TᵀT)`` is its standard diagonal approximation, exact in the
      limit of mass ∝ identity).
    """
    bh = 1.0 / np.sqrt(b_ext)
    mu, q = np.linalg.eigh(bh[:, None] * a_ext * bh[None, :])
    t = bh[:, None] * q
    return t, np.maximum(mu, 0.0), np.sum(t * t, axis=0)


def reference_element(n_degree: int) -> dict[str, np.ndarray]:
    """Bundle of reference-element constants for degree ``n_degree``.

    Returns:
      dict with ``nodes`` (N+1,), ``weights`` (N+1,), ``D`` (N+1, N+1) and
      ``weights3d`` ((N+1)^3,) — the tensor-product quadrature weights in
      (t, s, r) node order, matching the element-local field layout.
    """
    x, w = gll_nodes_weights(n_degree)
    d = derivative_matrix(n_degree)
    # 3-D tensor-product quadrature weights, node-ordered (t, s, r) row-major
    w3 = (w[:, None, None] * w[None, :, None] * w[None, None, :]).reshape(-1)
    return {"nodes": x, "weights": w, "D": d, "weights3d": w3}
