"""Screened Poisson operator: SPD, dense-assembly agreement, storage modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_problem,
    cg_assembled,
    cg_scattered,
    poisson_assembled,
    poisson_scattered,
)
from repro.core.gather_scatter import gather, gather_scatter, scatter


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(3, (2, 2, 2), lam=0.7, deform=0.15, dtype=jnp.float64)


def test_operator_symmetric_positive_definite(prob64):
    a = poisson_assembled(prob64)
    ng = prob64.n_global
    amat = np.array(jax.vmap(a, in_axes=1, out_axes=1)(jnp.eye(ng)))
    assert np.abs(amat - amat.T).max() < 1e-10 * np.abs(amat).max()
    eig = np.linalg.eigvalsh(amat)
    assert eig.min() > 0.69  # screened by lam=0.7


def test_constant_vector_hits_screen_only(prob64):
    """S @ 1 = 0 (Laplacian kills constants) so A @ 1 = lam * 1."""
    a = poisson_assembled(prob64)
    one = jnp.ones((prob64.n_global,), jnp.float64)
    np.testing.assert_allclose(np.array(a(one)), 0.7, atol=1e-10)


def test_scattered_equals_assembled(prob64):
    """Z^T W b_L == A x_G — the two storage modes are the same operator."""
    rng = np.random.default_rng(0)
    xg = jnp.asarray(rng.standard_normal(prob64.n_global))
    xl = scatter(xg, prob64.l2g)
    bl = poisson_scattered(prob64)(xl)
    bg = gather(prob64.w_local * bl, prob64.l2g, prob64.n_global)
    np.testing.assert_allclose(
        np.array(bg), np.array(poisson_assembled(prob64)(xg)), atol=1e-10
    )


def test_gather_scatter_projection(prob64):
    """ZZ^T is idempotent on consistent vectors: ZZ^T Z x = deg * ... and
    the assembled roundtrip Z^T W Z = I."""
    rng = np.random.default_rng(1)
    xg = jnp.asarray(rng.standard_normal(prob64.n_global))
    xl = scatter(xg, prob64.l2g)
    # Z^T W Z = I
    back = gather(prob64.w_local * xl, prob64.l2g, prob64.n_global)
    np.testing.assert_allclose(np.array(back), np.array(xg), atol=1e-12)


def test_cg_solves_both_modes(prob64):
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    a = poisson_assembled(prob64)
    res = cg_assembled(a, b, n_iter=200, record_history=True)
    rel = np.linalg.norm(np.array(a(res.x) - b)) / np.linalg.norm(np.array(b))
    assert rel < 1e-8
    hist = np.array(res.rdotr_history)
    assert hist[-1] < hist[0]

    bl = scatter(b, prob64.l2g)
    res2 = cg_scattered(poisson_scattered(prob64), bl, prob64.w_local, n_iter=200)
    xg = gather(prob64.w_local * res2.x, prob64.l2g, prob64.n_global)
    np.testing.assert_allclose(np.array(xg), np.array(res.x), atol=1e-6)


def test_mesh_jacobian_volume():
    """Sum of JW over all nodes = volume of the box, even deformed."""
    from repro.core import build_box_mesh, geometric_factors

    for deform in (0.0, 0.2):
        m = build_box_mesh(4, (2, 3, 2), extent=(1.0, 2.0, 0.5), deform=deform)
        geo = geometric_factors(m)
        np.testing.assert_allclose(geo["JW"].sum(), 1.0 * 2.0 * 0.5, rtol=1e-10)


def test_fom_formulas():
    from repro.core import fom

    e, n = 100, 7
    assert fom.nekbone_flops_per_iter(e, n) == 12 * e * 8**4 + 34 * e * 8**3
    assert fom.hipbone_flops_per_iter(e, n) < fom.nekbone_flops_per_iter(e, n)
    assert fom.operator_bytes(e, n, word=8) == 8 * e * n**3 + 68 * e * 8**3
    # roofline: memory-bound at any N <= 15 for TPU-class ratios
    for nn in range(1, 16):
        r = fom.roofline_gflops(nn, peak_gflops=197000, bandwidth_gbs=819, word=4)
        assert r < 197000
