"""Exchange-plan subsystem tests (comms.plan + comms.autotune).

Single-process tests cover the pure-python plan logic (forced policies,
routing menus, signature behavior); subprocess tests (8 fake CPU devices)
cover the timed sweep, persistence round-trip, and the solver-level
contract that every routing policy yields bit-identical PCG iteration
counts and statuses.
"""
import pytest

from conftest import run_subprocess


# ---------------------------------------------------------------- pure python
def test_resolve_routing_menus():
    from repro.comms import plan as xplan

    # sum sites have a staged route; the pair shells fall back cleanly
    assert xplan.resolve_routing("sum", "crystal") == "crystal"
    for kind in ("copy", "expand", "contract"):
        assert xplan.resolve_routing(kind, "crystal") == "face_sweep"
    for kind in ("sum", "copy", "expand", "contract"):
        assert xplan.resolve_routing(kind, "face_sweep") == "face_sweep"
        assert xplan.resolve_routing(kind, "fused") == "fused"
    with pytest.raises(ValueError, match="unknown exchange routing"):
        xplan.resolve_routing("sum", "pigeon")


def test_forced_plan_skips_timing_entirely():
    """A non-auto policy never touches the mesh: no timings, no persistence."""
    from repro.comms import plan as xplan

    plan = xplan.build_exchange_plan(
        None, None, "ranks", [], policy="crystal"
    )  # mesh=None proves the forced path never uses it
    assert not plan.timed and not plan.from_cache and not plan.sites
    assert plan.lookup("sum", 0) == ("crystal", None)
    assert plan.lookup("sum", 3) == ("crystal", None)  # any level
    # pair kinds: crystal policy falls back to the face sweep
    for kind in ("copy", "expand", "contract"):
        assert plan.lookup(kind, 0) == ("face_sweep", None)

    with pytest.raises(ValueError, match="unknown exchange policy"):
        xplan.build_exchange_plan(None, None, "ranks", [], policy="bogus")


def test_default_policy_env(monkeypatch):
    from repro.comms import plan as xplan

    monkeypatch.delenv("HIPBONE_EXCHANGE", raising=False)
    assert xplan.default_policy() == "face_sweep"
    monkeypatch.setenv("HIPBONE_EXCHANGE", "fused")
    assert xplan.default_policy() == "fused"
    monkeypatch.setenv("HIPBONE_EXCHANGE_CACHE", "")
    assert xplan.plan_cache_dir() is None  # empty string disables persistence


def test_site_descriptor_shares_level():
    """Same-shaped sites at different levels share one timing class."""
    from repro.comms.plan import ExchangeSite

    a = ExchangeSite("sum", 1, (3, 5, 5), "float64")
    b = ExchangeSite("sum", 2, (3, 5, 5), "float64")
    assert a.key != b.key
    assert a.descriptor() == b.descriptor()
    assert a.descriptor() != ExchangeSite("sum", 1, (3, 5, 7), "float64").descriptor()
    assert a.descriptor() != ExchangeSite("copy", 1, (3, 5, 5), "float64").descriptor()


# ------------------------------------------------------------- timed + disk
def test_plan_persistence_roundtrip():
    """auto plan: timed once, memoized in-process, reloaded from disk."""
    run_subprocess(
        """
import os, tempfile
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid
from repro.comms import plan as xplan
from repro.core.distributed import (
    build_dist_problem, build_pmg_levels, _exchange_sites, _schwarz_setup,
)
from repro.core.precond import SCHWARZ_INNER_DEGREE

grid = ProcessGrid((2, 2, 2))
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(3, grid, (2, 1, 1), lam=1.0, dtype=jnp.float64)
levels, _ = build_pmg_levels(prob, None)
schwarz = [
    _schwarz_setup(lvl, min(1, lvl.n_degree - 1), SCHWARZ_INNER_DEGREE)
    for lvl in levels[:-1]
]
sites = _exchange_sites(prob, levels, schwarz)
assert {s.key for s in sites} >= {"sum@0", "copy@0", "expand@0", "contract@0"}

with tempfile.TemporaryDirectory() as tmp:
    p1 = xplan.build_exchange_plan(
        mesh, grid, "ranks", sites, policy="auto", repeats=1, cache_dir=tmp)
    assert p1.timed and not p1.from_cache
    assert set(p1.sites) == {s.key for s in sites}
    for sp in p1.sites.values():
        assert sp.timings and sp.routing == min(
            sp.timings, key=sp.timings.get).split("/")[0]
        assert sp.wire_dtype is None       # wire="native" never narrows
        assert sp.bytes > 0
    # same-shape coarse levels share one timing sweep (same dict object)
    files = set(os.listdir(tmp))
    assert len(files) == 1                 # one plan file persisted

    # in-process memo: second build is the very same object, no new files
    p2 = xplan.build_exchange_plan(
        mesh, grid, "ranks", sites, policy="auto", repeats=1, cache_dir=tmp)
    assert p2 is p1 and set(os.listdir(tmp)) == files

    # disk round-trip: drop the memo, the plan reloads without re-timing
    xplan._MEMORY.clear()
    p3 = xplan.build_exchange_plan(
        mesh, grid, "ranks", sites, policy="auto", repeats=1, cache_dir=tmp)
    assert p3.from_cache and not p3.timed
    assert p3.signature == p1.signature
    for k in p1.sites:
        kind, lvl = k.split("@")
        assert p3.lookup(kind, int(lvl)) == p1.lookup(kind, int(lvl))

    # a different wire axis is a different signature (won't cross-load)
    xplan._MEMORY.clear()
    p4 = xplan.build_exchange_plan(
        mesh, grid, "ranks", sites, policy="auto", repeats=1, cache_dir=tmp,
        wire="auto")
    assert p4.signature != p1.signature and not p4.from_cache
    # fp64 boxes got an fp32 wire candidate in the auto sweep
    assert any("/float32" in lbl
               for sp in p4.sites.values() for lbl in sp.timings)

    # clear_plan_cache wipes both layers
    xplan.clear_plan_cache(cache_dir=tmp)
    assert not xplan._MEMORY and not os.listdir(tmp)
print("OK")
"""
    )


def test_autotune_mesh_key_and_nonpow2():
    """Content-keyed autotune cache + crystal filtered on non-pow2 axes."""
    run_subprocess(
        """
import jax, numpy as np
from repro.compat import make_mesh
from repro.comms import autotune

m1 = make_mesh((6,), ("r",))
# equivalent mesh built a different way (jax may or may not intern them —
# the content key must not care either way)
m2 = jax.sharding.Mesh(np.array(jax.devices()).reshape(6), ("r",))
assert autotune._mesh_key(m1) == autotune._mesh_key(m2)
# a different axis layout over the same devices is a different identity
m3 = make_mesh((2, 3), ("a", "b"))
assert autotune._mesh_key(m3) != autotune._mesh_key(m1)

w1 = autotune.autotune_exchange(m1, "r", (4,), repeats=1)
n_entries = len(autotune._CACHE)
w2 = autotune.autotune_exchange(m2, "r", (4,), repeats=1)
assert w2 == w1
assert len(autotune._CACHE) == n_entries   # content key hit, no re-time

# 6 ranks: the crystal router needs a power of two and must be filtered
# even when explicitly offered
w3 = autotune.autotune_exchange(
    m1, "r", (8,), repeats=1,
    candidates=("crystal_router", "pairwise"))
assert w3 == "pairwise", w3

autotune.clear_cache()
assert not autotune._CACHE
print("OK")
""",
        devices=6,
    )


# ---------------------------------------------------------------- solver level
def test_solve_policy_identical_iterations():
    """Every routing policy: same PCG iterations/status; x to ~1 ulp.

    The exchange primitives are bitwise-identical across routings at the
    native wire; the full solves still go through *different* XLA programs
    (different comm graphs change fusion/FMA decisions elsewhere), so x is
    compared to 1e-11 while iteration counts and statuses are exact.
    """
    run_subprocess(
        """
import os
os.environ["HIPBONE_EXCHANGE_CACHE"] = ""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid
from repro.core.distributed import build_dist_problem, dist_cg

grid = ProcessGrid((2, 2, 2))
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(2, grid, (1, 1, 2), lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((grid.size, prob.m3)))

results = {}
for policy in ("face_sweep", "crystal", "fused"):
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=40, tol=1e-9,
                          precond="pmg", exchange=policy))
    x, rdotr, iters, status, _ = run()
    results[policy] = (np.array(x), int(iters), int(status))
ref = results["face_sweep"]
for policy in ("crystal", "fused"):
    x, iters, status = results[policy]
    assert (iters, status) == ref[1:], (policy, iters, status, ref[1:])
    assert np.allclose(x, ref[0], rtol=0, atol=1e-11), (
        policy, np.abs(x - ref[0]).max())

# auto policy: times the sites, still lands on the same trajectory
run = dist_cg(prob, mesh, b, n_iter=40, tol=1e-9,
              precond="pmg", exchange="auto")
plan = run.exchange_plan
assert plan.timed and plan.sites
x, rdotr, iters, status, _ = jax.jit(run)()
assert (int(iters), int(status)) == ref[1:]

# cross-level overlap off: same math, different schedule
run = jax.jit(dist_cg(prob, mesh, b, n_iter=40, tol=1e-9,
                      precond="pmg", exchange="face_sweep",
                      vcycle_overlap=False))
x, rdotr, iters, status, _ = run()
assert (int(iters), int(status)) == ref[1:]
assert np.allclose(np.array(x), ref[0], rtol=0, atol=1e-11)
print("OK iters", ref[1])
""",
        timeout=900,
    )
