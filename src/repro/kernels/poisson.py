"""Pallas TPU kernel for the fused hipBone operator  y_L = (S_L + λW) x_L.

TPU adaptation of the paper's CUDA/HIP operator kernel (DESIGN.md §3):

* GPU version: one threadblock per element (3-D block for N<9, 2-D
  layer-by-layer for N>=9), shared memory as scratchpad, multiple elements
  per block to avoid masked lanes.
* TPU version: grid over *blocks of elements*; each grid step streams a
  (block_e, p) tile of DOFs plus its (block_e, 6, p) geometric factors and
  (block_e, p) weights HBM->VMEM, performs the three tensor-product
  contractions as element-batched ``dot_general``s (element batch folded
  into the matmul M dimension so the MXU sees tall-skinny matmuls instead
  of (N+1)x(N+1) crumbs), and writes the single output tile. The kernel is
  a single pass over all seven input streams — the paper's "perfect
  caching" traffic bound  word*N_G + (4 + 8*word)*N_L  is met by
  construction, because nothing is re-read.
* The GPU occupancy knob (registers/warp) becomes the VMEM-footprint knob
  ``block_e``, swept in benchmarks/table1_blocks.py.

The scatter Z (indirect read of x_G) happens outside at the XLA level —
TPU has no efficient per-lane random HBM gather inside a kernel; XLA's
dynamic-gather already streams it (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "poisson_local_pallas",
    "local_body",
    "vmem_bytes_per_block",
    "pick_block_e",
]


def local_body(u, g, w, d, *, lam: float, n1: int):
    """The three-contraction MXU body: (S_L + λW) u for one element block.

    Shared between the element-local kernel below and the single-pass fused
    assembled kernel (kernels/poisson_fused.py). Pure function of VMEM-
    resident values; returns the (Eb, p) result in the accumulation dtype
    (``promote_types(u.dtype, f32)`` — fp64 inputs accumulate in fp64).
    """
    eb, p = u.shape
    f32 = jnp.float32
    acc = jnp.promote_types(u.dtype, f32)

    u3 = u.reshape(eb, n1, n1, n1).astype(acc)
    dd = d.astype(acc)

    # --- gradient: three element-batched contractions --------------------
    # r-derivative: fold (e, t, s) into M -> (M, n1) @ (n1, n1)^T, MXU-shaped.
    ur = jax.lax.dot_general(
        u3.reshape(eb * n1 * n1, n1), dd,
        ((((1,), (1,)), ((), ()))),
        preferred_element_type=acc,
    ).reshape(eb, n1, n1, n1)
    # s-derivative: contract the middle axis; einsum lowers to
    # dot_general + layout change, which Mosaic pipelines with the matmul.
    us = jnp.einsum("jb,etbr->etjr", dd, u3, preferred_element_type=acc)
    # t-derivative
    ut = jnp.einsum("kc,ecsr->eksr", dd, u3, preferred_element_type=acc)

    # --- metric: 15 (N+1)^3 FLOPs/elt, pure VPU ---------------------------
    g3 = g.reshape(eb, 6, n1, n1, n1).astype(acc)
    wr = g3[:, 0] * ur + g3[:, 1] * us + g3[:, 2] * ut
    ws = g3[:, 1] * ur + g3[:, 3] * us + g3[:, 4] * ut
    wt = g3[:, 2] * ur + g3[:, 4] * us + g3[:, 5] * ut

    # --- divergence: transposed contractions ------------------------------
    out = jax.lax.dot_general(
        wr.reshape(eb * n1 * n1, n1), dd,
        ((((1,), (0,)), ((), ()))),
        preferred_element_type=acc,
    ).reshape(eb, n1, n1, n1)
    out = out + jnp.einsum("jb,etjr->etbr", dd, ws, preferred_element_type=acc)
    out = out + jnp.einsum("kc,eksr->ecsr", dd, wt, preferred_element_type=acc)

    # --- fused screen λW --------------------------------------------------
    return out.reshape(eb, p) + lam * (w.astype(acc) * u.astype(acc))


def _kernel(u_ref, g_ref, w_ref, d_ref, out_ref, *, lam: float, n1: int):
    """One grid step: apply (S_L + λW) to block_e elements resident in VMEM."""
    out = local_body(
        u_ref[...], g_ref[...], w_ref[...], d_ref[...], lam=lam, n1=n1
    )
    out_ref[...] = out.astype(out_ref.dtype)


def vmem_bytes_per_block(block_e: int, n1: int, dtype=jnp.float32) -> int:
    """Estimated VMEM working set of one grid step (inputs+outputs+temps)."""
    p = n1**3
    word = jnp.dtype(dtype).itemsize
    io = block_e * p * (1 + 6 + 1 + 1) * word        # u, G, w, out tiles
    tmp = block_e * p * 6 * 4                        # ur/us/ut + wr/ws/wt (f32)
    return io + tmp


def pick_block_e(
    n_degree: int, dtype=jnp.float32, budget_bytes: int = 4 * 2**20
) -> int:
    """Largest power-of-two element block whose working set fits the budget.

    The 4 MB default leaves VMEM room for double-buffered pipelining
    (Mosaic overlaps the next tile's HBM->VMEM DMA with current compute,
    the TPU analogue of the paper's >1 waves/CU occupancy goal).
    """
    n1 = n_degree + 1
    eb = 256
    while eb > 1 and vmem_bytes_per_block(eb, n1, dtype) > budget_bytes:
        eb //= 2
    return eb


@functools.partial(
    jax.jit,
    static_argnames=("lam", "block_e", "interpret"),
)
def poisson_local_pallas(
    u: jax.Array,
    g: jax.Array,
    w: jax.Array,
    d: jax.Array,
    *,
    lam: float,
    block_e: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused (S_L + λW) u for element-blocked tiles.

    Args:
      u: (E, p) local DOFs, p=(N+1)^3. E must be a multiple of block_e
         (ops.poisson_local pads).
      g: (E, 6, p) packed geometric factors.
      w: (E, p) inverse-degree weights (pass ones for the plain S_L + λI).
      d: (n1, n1) derivative matrix.
      lam: screen parameter (static).
      block_e: elements per grid step; default via pick_block_e.
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      (E, p) y_L.
    """
    e, p = u.shape
    n1 = d.shape[0]
    if n1**3 != p:
        raise ValueError(f"p={p} is not (N+1)^3 for n1={n1}")
    eb = block_e or pick_block_e(n1 - 1, u.dtype)
    eb = min(eb, e)
    if e % eb:
        raise ValueError(f"E={e} not a multiple of block_e={eb}; use ops.poisson_local")
    grid = (e // eb,)

    return pl.pallas_call(
        functools.partial(_kernel, lam=lam, n1=n1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((eb, p), lambda i: (i, 0)),
            pl.BlockSpec((eb, 6, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((eb, p), lambda i: (i, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((eb, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, p), u.dtype),
        interpret=interpret,
    )(u, g, w, d)
